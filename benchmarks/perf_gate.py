"""Perf-regression gate over the committed BENCH_*.json trajectory.

CI runs ``python -m benchmarks.run --tag ci --json`` to produce a fresh
``BENCH_ci.json``, then ``python -m benchmarks.perf_gate BENCH_ci.json``
compares it per-method against the newest committed trajectory point
(``BENCH_N.json`` with the highest numeric N — ``git ls-files`` so only
committed baselines count, never a stale working-tree file).  A method
cell regresses when its wall time exceeds ``tolerance ×`` the baseline's
(default 1.3).

Raw wall times are useless across machines (the committed baseline ran on
whatever container produced that PR), so by default each method's wall
time is first normalized by the same file's ``direct`` row — the LAPACK
QR solve, a pure-BLAS yardstick that scales with the host like every
other cell.  ``--absolute`` compares raw seconds instead (sensible only
on the machine that produced the baseline).

Serve rows (PR 7) are gated on three more metrics wherever present:

- ``solves_per_s`` — throughput, HIGHER is better, so the regression
  ratio is inverted; normalized by the ``direct`` yardstick like wall
  times (solves/sec × direct-seconds is dimensionless).
- ``speedup`` — batched-vs-per-request ratio, already dimensionless, so
  compared absolutely; additionally held to the hard ≥5x acceptance
  floor whenever the row exists, baseline or not.
- ``p99_s`` — open-loop tail latency, compared absolutely: it is
  dominated by the service's batching *window* (a configuration
  constant), so normalizing by machine speed would punish faster hosts.

Cluster rows (PR 8) add two more:

- ``tiles_per_s`` — pass-1 streaming throughput across the worker pool,
  HIGHER is better, normalized by the ``direct`` yardstick.
- ``overhead_x`` — kill-and-resume wall time over the uninterrupted
  cluster solve, dimensionless so compared absolutely; additionally held
  to the hard ≤1.5x acceptance CEILING whenever the row exists (recovery
  resumes from the accumulator checkpoint, so it must never approach a
  full restart's ~2x).

The obs row (PR 9) reuses ``overhead_x``: ``obs_overhead`` is the same
solve timed with tracing disabled over a stripped build (instrumentation
entry points swapped for bare no-ops), held to a hard ≤1.05x CEILING —
observability nobody asked for must cost within noise of nothing.  The
row's ``traced_x`` (tracing ON, which deliberately synchronizes async
dispatch per span) is informational and not gated.

Exit codes: 0 = no regression (or no committed baseline yet — the gate
bootstraps quietly), 1 = at least one regressed cell or missed floor,
2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import re
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
NORM_ROW = "direct"

# (metric, lower_is_better, normalized): wall times and throughput scale
# with the host so they are measured in direct-row units; speedup and the
# window-dominated open-loop p99 are compared absolutely.
METRICS = (
    ("wall_s", True, True),
    ("solves_per_s", False, True),
    ("speedup", False, False),
    ("p99_s", True, False),
    ("tiles_per_s", False, True),
    ("overhead_x", True, False),
)

# Hard floors checked on the FRESH file alone (acceptance criteria that
# must hold even with no committed baseline): row name -> (metric, min).
FLOORS = {"serve_speedup": ("speedup", 5.0)}

# Hard ceilings, same contract with the inequality flipped:
# row name -> (metric, max).
CEILINGS = {
    "cluster_resume_overhead": ("overhead_x", 1.5),
    "obs_overhead": ("overhead_x", 1.05),
}


def committed_baselines(root: Path = REPO_ROOT) -> list[tuple[int, Path]]:
    """(N, path) for every git-tracked BENCH_<N>.json, N numeric, ascending."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "BENCH_*.json"],
            cwd=root, capture_output=True, text=True, check=True,
        ).stdout
    except (OSError, subprocess.CalledProcessError):
        return []
    found = []
    for line in out.splitlines():
        m = re.fullmatch(r"BENCH_(\d+)\.json", line.strip())
        if m:
            found.append((int(m.group(1)), root / line.strip()))
    return sorted(found)


def load_rows(path: Path) -> dict[str, dict]:
    with open(path) as fh:
        payload = json.load(fh)
    rows = {r["name"]: r for r in payload.get("rows", [])}
    if not rows:
        raise ValueError(f"{path}: no rows")
    return rows


def check_floors(fresh: dict[str, dict]) -> list[str]:
    """Absolute acceptance floors/ceilings on the fresh file
    (baseline-independent)."""
    failures = []
    for name, (metric, floor) in FLOORS.items():
        row = fresh.get(name)
        if row is None or metric not in row:
            continue
        val = row[metric]
        if val < floor:
            failures.append(
                f"FLOOR {name}.{metric}: {val:.3g} < required {floor:.3g}"
            )
        else:
            print(f"ok {name}.{metric}: {val:.3g} >= floor {floor:.3g}")
    for name, (metric, ceil) in CEILINGS.items():
        row = fresh.get(name)
        if row is None or metric not in row:
            continue
        val = row[metric]
        if val > ceil:
            failures.append(
                f"CEILING {name}.{metric}: {val:.3g} > allowed {ceil:.3g}"
            )
        else:
            print(f"ok {name}.{metric}: {val:.3g} <= ceiling {ceil:.3g}")
    return failures


def compare(
    fresh: dict[str, dict],
    base: dict[str, dict],
    *,
    tolerance: float,
    normalize: bool,
) -> list[str]:
    """Human-readable report lines for every regressed method cell."""
    scale_f = scale_b = 1.0
    if normalize:
        if NORM_ROW not in fresh or NORM_ROW not in base:
            raise ValueError(
                f"normalization row {NORM_ROW!r} missing "
                "(pass --absolute to compare raw seconds)"
            )
        scale_f = fresh[NORM_ROW]["wall_s"]
        scale_b = base[NORM_ROW]["wall_s"]
    failures = []
    for name in sorted(set(fresh) & set(base)):
        if normalize and name == NORM_ROW:
            continue  # the yardstick is 1.0 vs 1.0 by construction
        for metric, lower_better, metric_norm in METRICS:
            if metric not in fresh[name] or metric not in base[name]:
                continue
            # throughput in direct-row units multiplies by the yardstick
            # (solves/sec x seconds is dimensionless); times divide by it
            if normalize and metric_norm:
                if lower_better:
                    v_f = fresh[name][metric] / scale_f
                    v_b = base[name][metric] / scale_b
                else:
                    v_f = fresh[name][metric] * scale_f
                    v_b = base[name][metric] * scale_b
            else:
                v_f = fresh[name][metric]
                v_b = base[name][metric]
            if v_b <= 0 or v_f <= 0:
                continue
            # ratio > 1 always means "fresh is worse"
            ratio = v_f / v_b if lower_better else v_b / v_f
            unit = "x direct" if (normalize and metric_norm) else ""
            label = name if metric == "wall_s" else f"{name}.{metric}"
            if ratio > tolerance:
                failures.append(
                    f"REGRESSION {label}: {v_f:.4g}{unit} vs baseline "
                    f"{v_b:.4g}{unit} ({ratio:.2f}x > {tolerance:.2f}x)"
                )
            else:
                print(f"ok {label}: {ratio:.2f}x vs baseline "
                      f"(tol {tolerance:.2f}x)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="fresh bench JSON (e.g. BENCH_ci.json)")
    ap.add_argument(
        "--baseline", default=None,
        help="explicit baseline JSON (default: committed BENCH_N.json "
             "with the highest N)",
    )
    ap.add_argument(
        "--tolerance", type=float, default=1.3,
        help="max allowed fresh/baseline wall-time ratio per method cell "
             "(default 1.3)",
    )
    ap.add_argument(
        "--absolute", action="store_true",
        help="compare raw seconds instead of direct-row-normalized times",
    )
    args = ap.parse_args(argv)

    fresh_path = Path(args.fresh)
    if not fresh_path.exists():
        print(f"perf_gate: fresh bench file {fresh_path} not found", file=sys.stderr)
        return 2
    fresh = load_rows(fresh_path)
    failures = check_floors(fresh)

    if args.baseline is not None:
        base_path = Path(args.baseline)
        if not base_path.exists():
            print(f"perf_gate: baseline {base_path} not found", file=sys.stderr)
            return 2
    else:
        baselines = committed_baselines()
        if not baselines:
            if failures:
                for line in failures:
                    print(line, file=sys.stderr)
                return 1
            print("perf_gate: no committed BENCH_N.json baseline yet — pass")
            return 0
        base_path = baselines[-1][1]

    base = load_rows(base_path)
    print(f"perf_gate: {fresh_path.name} vs {base_path.name} "
          f"(tolerance {args.tolerance}x, "
          f"{'absolute' if args.absolute else f'normalized by {NORM_ROW!r}'})")
    failures += compare(
        fresh, base, tolerance=args.tolerance, normalize=not args.absolute
    )
    for line in failures:
        print(line, file=sys.stderr)
    if failures:
        print(f"perf_gate: {len(failures)} regressed cell(s)", file=sys.stderr)
        return 1
    print("perf_gate: pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
