"""Paper Figure 3: runtime vs m for SAA-SAS vs LSQR.

Paper sweep: m equally log-spaced in [2^12, 2^20], n=1000.  Default here is
capped at 2^17 with n=256 (single CPU core, see DESIGN.md §7 deviations);
``--full`` restores the paper sizes.  Problem generation uses the 'fast'
§5.1 variant (Gaussian left factor) so generation cost does not drown the
solver comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import generate_problem, lsqr_dense, saa_sas

from .common import emit, time_fn


def run(full=False, seed=0):
    n = 1000 if full else 256
    max_pow = 20 if full else 17
    sizes = [2**p for p in range(12, max_pow + 1, 2 if not full else 1)]
    key = jax.random.key(seed)

    for m in sizes:
        prob = generate_problem(
            jax.random.key(seed), m, n, cond=1e10, beta=1e-10, method="fast"
        )
        A, b = prob.A, prob.b

        t_saa = time_fn(lambda: saa_sas(A, b, key), repeats=3)
        r = saa_sas(A, b, key)
        emit(f"fig3/saa_sas/m{m}", t_saa, f"n={n};itn={int(r.itn)}")

        t_lsqr = time_fn(lambda: lsqr_dense(A, b, iter_lim=2 * n), repeats=3)
        rl = lsqr_dense(A, b, iter_lim=2 * n)
        emit(
            f"fig3/lsqr/m{m}",
            t_lsqr,
            f"n={n};itn={int(rl.itn)};speedup={t_lsqr / t_saa:.2f}x",
        )
