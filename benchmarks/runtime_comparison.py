"""Paper Figure 3: runtime vs m for SAA-SAS vs LSQR — per backend, plus the
forward-stable solvers (iterative sketching, FOSSILS) on the reference
backend so their overhead relative to SAA-SAS is visible per size, and the
``SketchedSolver`` serving row: one session (sketch+QR built once) serving
k right-hand sides vs k independent ``lstsq()`` calls — the amortized
multi-RHS speedup.

Paper sweep: m equally log-spaced in [2^12, 2^20], n=1000.  Default here is
capped at 2^17 with n=256 (single CPU core, see DESIGN.md §7 deviations);
``--full`` restores the paper sizes.  Problem generation uses the 'fast'
§5.1 variant (Gaussian left factor) so generation cost does not drown the
solver comparison.

``saa_sas`` is timed once per backend (``reference`` and ``pallas``) so the
trajectory attributes every point to the code path that produced it.  Off-
TPU the pallas backend runs in interpret mode — faithful semantics, very
slow — so it is swept only up to ``PALLAS_INTERP_MAX_M`` rows there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    SketchedSolver,
    fossils,
    generate_problem,
    iterative_sketching,
    lsqr_dense,
    lstsq,
    resolve_backend,
    saa_sas,
)

from .common import emit, time_fn

# interpret-mode pallas is O(grid) python; keep its sweep bounded off-TPU
PALLAS_INTERP_MAX_M = 2**14

# right-hand sides per design matrix for the serving-amortization row
MULTI_RHS_K = 8


def run(full=False, seed=0):
    n = 1000 if full else 256
    max_pow = 20 if full else 17
    sizes = [2**p for p in range(12, max_pow + 1, 2 if not full else 1)]
    key = jax.random.key(seed)

    for m in sizes:
        prob = generate_problem(
            jax.random.key(seed), m, n, cond=1e10, beta=1e-10, method="fast"
        )
        A, b = prob.A, prob.b

        t_saa = None
        for backend in ("reference", "pallas"):
            rb = resolve_backend(backend)
            if rb.interpret and backend == "pallas" and m > PALLAS_INTERP_MAX_M:
                continue
            t = time_fn(lambda: saa_sas(A, b, key, backend=backend), repeats=3)
            r = saa_sas(A, b, key, backend=backend)
            emit(
                f"fig3/saa_sas/{backend}/m{m}",
                t,
                f"backend={rb.name};interpret={int(rb.interpret)};"
                f"n={n};itn={int(r.itn)}",
            )
            if backend == "reference":
                t_saa = t

        t_lsqr = time_fn(lambda: lsqr_dense(A, b, iter_lim=2 * n), repeats=3)
        rl = lsqr_dense(A, b, iter_lim=2 * n)
        emit(
            f"fig3/lsqr/m{m}",
            t_lsqr,
            f"n={n};itn={int(rl.itn)};speedup={t_lsqr / t_saa:.2f}x",
        )

        # Forward-stable solvers, pinned to the reference backend so the
        # vs_saa ratio against the reference-backend SAA time isolates
        # algorithmic overhead (not backend differences).
        t_it = time_fn(
            lambda: iterative_sketching(A, b, key, backend="reference"), repeats=3
        )
        ri = iterative_sketching(A, b, key, backend="reference")
        emit(
            f"fig3/iterative_sketching/m{m}",
            t_it,
            f"n={n};itn={int(ri.itn)};vs_saa={t_it / t_saa:.2f}x",
        )
        t_fo = time_fn(lambda: fossils(A, b, key, backend="reference"), repeats=3)
        rf = fossils(A, b, key, backend="reference")
        emit(
            f"fig3/fossils/m{m}",
            t_fo,
            f"n={n};itn={int(rf.itn)};vs_saa={t_fo / t_saa:.2f}x",
        )

        # Serving amortization: ONE SketchedSolver session (build + k
        # solves via solve_many) vs k independent lstsq() calls, each of
        # which redraws, re-sketches and re-factors.  The session time
        # INCLUDES the sketch+QR build, so the ratio is the honest
        # amortized multi-RHS speedup.
        k = MULTI_RHS_K
        rhs = b[:, None] + 0.01 * jax.random.normal(
            jax.random.key(seed + 1), (m, k)
        )

        def session_run():
            solver = SketchedSolver(A, key, backend="reference")
            return solver.solve_many(rhs).x

        def independent_run():
            return [
                lstsq(A, rhs[:, i], key, method="saa", backend="reference").x
                for i in range(k)
            ]

        t_sess = time_fn(session_run, repeats=3)
        t_indep = time_fn(independent_run, repeats=3)
        emit(
            f"fig3/multi_rhs_session/m{m}",
            t_sess,
            f"n={n};k={k};per_rhs_us={t_sess / k * 1e6:.1f};"
            f"indep_us={t_indep * 1e6:.1f};"
            f"amortized_speedup={t_indep / t_sess:.2f}x",
        )
