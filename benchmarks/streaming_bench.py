"""Streaming sketch engine: streamed build throughput vs the monolithic
apply, plus the two-pass solve.

Each ``stream/<kind>/build`` row times one full accumulator pass (pass 1
of the streaming drivers) and derives ``tiles_per_s`` and the
peak-memory proxy ``peak_tile_frac`` = tile bytes / (m·n·8) — the
fraction of A resident at any point on the streamed path (the monolithic
rows hold all of it, ``peak_tile_frac=1``).  ``stream/solve/*`` compares
the two-pass ``stream_lstsq`` against the in-memory ``lstsq`` with the
same key (bit-identical S, so the numerics match; the delta is pure
streaming overhead).
"""
from __future__ import annotations

import jax

from repro.core import lstsq, sample_sketch
from repro.streaming import ArraySource, accumulate_source, stream_lstsq

from .common import emit, time_fn

OPERATORS = (
    "countsketch",
    "sparse_sign",
    "uniform_sparse",
    "srht",
    "gaussian",
    "uniform_dense",
)


def run(m=16384, n=64, d_mult=4, tile_rows=2048, seed=0):
    d = d_mult * n
    A = jax.random.normal(jax.random.key(seed), (m, n))
    b = jax.random.normal(jax.random.key(seed + 1), (m,))
    src = ArraySource(A, tile_rows=tile_rows)
    n_tiles = src.num_tiles
    tile_frac = tile_rows / m

    for kind in OPERATORS:
        op = sample_sketch(kind, jax.random.key(seed + 2), d, m)

        def build():
            return accumulate_source(op, src).finalize()

        t_stream = time_fn(build)
        t_mono = time_fn(lambda: op.apply(A))
        emit(
            f"stream/{kind}/build",
            t_stream,
            f"tiles_per_s={n_tiles / t_stream:.1f};tile_rows={tile_rows};"
            f"peak_tile_frac={tile_frac:.4f};d={d};m={m}",
        )
        emit(
            f"stream/{kind}/monolithic",
            t_mono,
            f"peak_tile_frac=1.0;stream_overhead_x={t_stream / t_mono:.2f};"
            f"d={d};m={m}",
        )

    key = jax.random.key(seed + 3)
    for method in ("sketch_and_solve", "iterative", "saa"):
        t_solve = time_fn(
            lambda: stream_lstsq(src, b, key, method=method).x
        )
        emit(
            f"stream/solve/{method}",
            t_solve,
            f"tile_rows={tile_rows};peak_tile_frac={tile_frac:.4f};m={m};n={n}",
        )
    t_dense = time_fn(lambda: lstsq(A, b, key, method="iterative").x)
    emit(
        f"stream/solve/dense_iterative",
        t_dense,
        f"peak_tile_frac=1.0;m={m};n={n}",
    )
