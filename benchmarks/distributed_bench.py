"""Distributed sketched least-squares: scaling + comm accounting.

Runs the shard_map SAA-SAS on however many host devices this process has
(1 on the default CPU container — the multi-device path is exercised by the
dry-run and tests/test_distributed_lsq.py, which spawn dedicated
processes), and reports the collective payload per solve: one s×(n+1)
all-reduce + one n-vector psum per LSQR iteration — independent of m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import generate_problem, sketched_lstsq
from repro.core.distributed import shard_rows

from .common import emit, time_fn


def run(m=32768, n=128, seed=0):
    ndev = len(jax.devices())
    mesh = jax.make_mesh(
        (ndev,), ("data",))
    prob = generate_problem(
        jax.random.key(seed), m, n, cond=1e10, beta=1e-10, method="fast"
    )
    A, b = shard_rows(mesh, ("data",), prob.A, prob.b)
    key = jax.random.key(seed + 1)

    t = time_fn(lambda: sketched_lstsq(A, b, key, mesh=mesh).x)
    r = sketched_lstsq(A, b, key, mesh=mesh)
    s = 4 * n
    sketch_bytes = s * (n + 1) * 8
    per_iter_bytes = (n + 3) * 8
    emit(
        "dist/sketched_lstsq",
        t,
        f"devices={ndev};itn={int(r.itn)};allreduce_bytes_sketch={sketch_bytes};"
        f"allreduce_bytes_per_lsqr_iter={per_iter_bytes};m_independent=True",
    )
