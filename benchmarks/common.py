"""Benchmark utilities: timing with block_until_ready + CSV emission."""
from __future__ import annotations

import time

import jax

ROWS: list[tuple] = []


def time_fn(fn, *args, warmup=1, repeats=3, **kw):
    """Median wall time (s) of fn(*args) with jitted-result sync."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = ""):
    """Print one ``name,us_per_call,derived`` CSV row (scaffold contract)."""
    row = (name, seconds * 1e6, derived)
    ROWS.append(row)
    print(f"{name},{seconds * 1e6:.1f},{derived}")
