"""Cluster engine: pass-1 scaling vs worker count + kill-and-resume cost.

Two row families:

- ``cluster/pass1/w<K>`` — one fault-tolerant pass-1 sketch over the
  pool at K workers; ``tiles_per_s`` is the scaling figure (the workers
  are threads sharing one CPU here, so this measures coordination
  overhead, not linear speedup — the number that must NOT collapse as K
  grows).
- ``cluster_resume_overhead`` — a full two-pass cluster solve, clean vs
  with a worker killed mid-pass-1 and recovered from its accumulator
  checkpoint.  ``overhead_x`` = faulted / clean wall time; the perf gate
  holds it to the ≤1.5x acceptance ceiling (recovery re-streams only the
  tiles past the watermark, so it must stay far from a full restart's
  ~2x).

``--smoke`` shrinks sizes for the examples-smoke CI lane.
"""
from __future__ import annotations

import tempfile

import jax

from repro.cluster import ClusterEngine, ClusterSpec, KillWorker
from repro.streaming import ArraySource, stream_lstsq, stream_sketch

from .common import emit, time_fn

WORKER_COUNTS = (1, 2, 4)


def _pass1(A, tile_rows, workers, d):
    """Time one pool-distributed pass-1 sketch (fresh engine per call so
    fault bookkeeping and checkpoints never leak across repeats)."""
    def run():
        with tempfile.TemporaryDirectory() as ckpt:
            eng = ClusterEngine(
                ArraySource(A, tile_rows=tile_rows),
                ClusterSpec(num_workers=workers, ckpt_dir=ckpt,
                            checkpoint_every=4),
            )
            B, _, _ = stream_sketch(eng, jax.random.key(2), sketch_size=d)
            eng.close()
            return B
    return run


def _solve(A, b, tile_rows, workers, d, faults):
    def run():
        with tempfile.TemporaryDirectory() as ckpt:
            eng = ClusterEngine(
                ArraySource(A, tile_rows=tile_rows),
                ClusterSpec(
                    num_workers=workers, ckpt_dir=ckpt, checkpoint_every=2,
                    faults=None if faults is None else list(faults),
                ),
            )
            x = stream_lstsq(eng, b, jax.random.key(3), method="saa",
                             sketch_size=d).x
            eng.close()
            return x
    return run


def run(m=16384, n=64, d_mult=4, tile_rows=512, seed=0, smoke=False):
    if smoke:
        m, n, tile_rows = 4000, 32, 250
    d = d_mult * n
    A = jax.random.normal(jax.random.key(seed), (m, n))
    b = jax.random.normal(jax.random.key(seed + 1), (m,))
    n_tiles = -(-m // tile_rows)
    rows = []

    for w in WORKER_COUNTS:
        t = time_fn(_pass1(A, tile_rows, w, d))
        tps = n_tiles / t
        emit(
            f"cluster/pass1/w{w}", t,
            f"tiles_per_s={tps:.1f};workers={w};tile_rows={tile_rows};"
            f"d={d};m={m}",
        )
        rows.append({
            "name": f"cluster_pass1_w{w}", "m": m, "n": n, "d": d,
            "workers": w, "tile_rows": tile_rows,
            "wall_s": t, "tiles_per_s": tps,
        })

    workers = 4
    t_clean = time_fn(_solve(A, b, tile_rows, workers, d, None))
    # kill a mid-pool worker a few tiles into its range, every repeat
    kill = (KillWorker(worker=1, at_tile=2),)
    t_kill = time_fn(_solve(A, b, tile_rows, workers, d, kill))
    overhead = t_kill / t_clean
    emit(
        "cluster/solve/clean", t_clean,
        f"workers={workers};tile_rows={tile_rows};m={m};n={n}",
    )
    emit(
        "cluster/solve/kill_resume", t_kill,
        f"workers={workers};overhead_x={overhead:.3f};m={m};n={n}",
    )
    rows.append({
        "name": "cluster_resume_overhead", "m": m, "n": n,
        "workers": workers, "tile_rows": tile_rows,
        "wall_s": t_kill, "wall_s_clean": t_clean,
        "overhead_x": overhead,
    })
    return rows


if __name__ == "__main__":
    import argparse

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI smoke lane")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        if "overhead_x" in row:
            assert row["overhead_x"] < 2.5, (
                f"kill-and-resume overhead {row['overhead_x']:.2f}x — "
                "recovery is re-running far more than the lost tiles"
            )
