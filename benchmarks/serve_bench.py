"""Serve-layer load harness — the BENCH_7.json ``serve`` trajectory rows.

Two load shapes against :class:`repro.serve.SolveService`:

- **Closed loop** (the acceptance scenario): 64 same-fingerprint requests
  land at once; the service answers them with ONE cached factor and ONE
  coalesced ``solve_many`` batch.  The baseline is the strongest honest
  per-request alternative — ``lstsq(accuracy="certified",
  certified_rtol=...)`` per request, the only per-request API whose
  responses also carry a certificate — so the speedup row compares
  equal-accuracy, equal-guarantee work.  Both the cold path (the first
  request pays the session build) and the warm path (cache hit) are
  reported; every response on both sides must carry a PASSING certificate
  for the requested rtol or the bench aborts.
- **Open loop**: Poisson arrivals at a fixed rate against the background
  pump thread, reporting achieved solves/sec, p50/p99 response latency,
  cache hit rate and mean batch occupancy — the tail-latency numbers the
  continuous-batching window (``max_delay_s``) is supposed to bound.

Rows land in ``run.py --json`` (``serve_*`` names) and are gated by
``benchmarks/perf_gate.py``: wall/throughput rows normalized by the
``direct`` yardstick, the dimensionless ``serve_speedup`` row against an
absolute ≥5x floor, open-loop p99 against its committed baseline.

``--smoke``: tiny sizes + a ~1s open loop, asserting the full machinery
(certificates, cache hits, rejections-free run) — the CI examples job.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import generate_problem, lstsq
from repro.serve import SolveService

from .common import emit, time_fn

# The acceptance scenario: this many same-fingerprint requests, one batch.
CLOSED_LOOP_K = 64
RTOL = 1e-6


def _make_problem(m, n, k, seed, cond=1e4, beta=1e-6):
    """One shared A (moderate cond — the serving regime) and k RHS."""
    prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
    A = prob.A
    kx, kr = jax.random.split(jax.random.key(seed + 1))
    X = jax.random.normal(kx, (n, k), A.dtype)
    X = X / jnp.linalg.norm(X, axis=0)
    R = jax.random.normal(kr, (m, k), A.dtype)
    RHS = A @ X + beta * R / jnp.linalg.norm(R, axis=0)
    return A, jax.block_until_ready(RHS)


def _check_all_certified(responses, rtol):
    for r in responses:
        if not r.ok:
            raise AssertionError(f"serve_bench: request rejected: {r.reason}")
        c = r.certificate
        if c is None or not bool(c.passed) or float(c.target) > rtol * 1.001:
            raise AssertionError(
                "serve_bench: response without a passing certificate for "
                f"rtol={rtol:g} (cert={c})"
            )


def closed_loop(m, n, k=CLOSED_LOOP_K, rtol=RTOL, seed=0):
    """Baseline-vs-service rows for the k-same-fingerprint burst."""
    A, RHS = _make_problem(m, n, k, seed)
    key = jax.random.key(seed + 2)

    def baseline():
        xs = []
        for j in range(k):
            res = lstsq(
                A, RHS[:, j], jax.random.fold_in(key, j),
                accuracy="certified", certified_rtol=rtol,
            )
            if res.certificate is None or not bool(res.certificate.passed):
                raise AssertionError(
                    "baseline certified lstsq failed its own certificate"
                )
            xs.append(res.x)
        return jnp.stack(xs)

    base_s = time_fn(baseline, warmup=1, repeats=1)

    def serve_cold():
        svc = SolveService(key, max_batch=k, max_delay_s=0.002)
        futs = [
            svc.submit(A, RHS[:, j], certified_rtol=rtol, mode="session")
            for j in range(k)
        ]
        svc.flush()
        resps = [f.result() for f in futs]
        _check_all_certified(resps, rtol)
        return resps, svc

    cold_s = time_fn(lambda: serve_cold()[0][0].x, warmup=1, repeats=3)

    # Warm path: the factor is cached, requests only pay the batch solve.
    svc = SolveService(key, max_batch=k, max_delay_s=0.002)

    def serve_warm():
        futs = [
            svc.submit(A, RHS[:, j], certified_rtol=rtol, mode="session")
            for j in range(k)
        ]
        svc.flush()
        resps = [f.result() for f in futs]
        _check_all_certified(resps, rtol)
        return resps

    warm_s = time_fn(serve_warm, warmup=1, repeats=3)
    stats = svc.stats()

    rows = [
        {
            "name": "serve_per_request_lstsq",
            "m": m, "n": n, "k": k, "rtol": rtol,
            "wall_s": base_s, "solves_per_s": k / base_s,
            "all_certified": True,
        },
        {
            "name": "serve_closed_cold",
            "m": m, "n": n, "k": k, "rtol": rtol,
            "wall_s": cold_s, "solves_per_s": k / cold_s,
            "all_certified": True,
        },
        {
            "name": "serve_closed_warm",
            "m": m, "n": n, "k": k, "rtol": rtol,
            "wall_s": warm_s, "solves_per_s": k / warm_s,
            "all_certified": True,
            "cache_hit_rate": stats["cache"]["hit_rate"],
        },
        {
            "name": "serve_speedup",
            "m": m, "n": n, "k": k, "rtol": rtol,
            "speedup": base_s / cold_s,
            "speedup_warm": base_s / warm_s,
        },
    ]
    emit("serve/per_request_lstsq", base_s, f"k={k};rtol={rtol:g}")
    emit("serve/closed_cold", cold_s,
         f"k={k};speedup={base_s / cold_s:.2f}x")
    emit("serve/closed_warm", warm_s,
         f"k={k};speedup={base_s / warm_s:.2f}x")
    return rows


def open_loop(m, n, rate_hz=60.0, duration_s=2.5, rtol=RTOL, seed=0,
              n_tenants=3):
    """Poisson arrivals across a few tenants against the pump thread.

    Sized for the latency story, not the flop story: per-dispatch cost is
    flat in batch width (the vmapped LSQR iterates until the slowest
    column converges), so the sustainable rate is width/dispatch — the
    closed-loop rows show the width lever, this row shows the tail the
    2ms batching window buys at a comfortably sub-capacity arrival rate.
    """
    k_pool = 32
    tenants = [
        _make_problem(m, n, k_pool, seed + 10 * t) for t in range(n_tenants)
    ]
    svc = SolveService(jax.random.key(seed + 3), max_batch=32,
                       max_delay_s=0.002)
    # Warmup requests: build every tenant's factor and compile the
    # batch-width ladder so the measured window sees steady-state serving.
    for A, _ in tenants:
        svc.prewarm(A)
    svc.start(poll_s=2e-4)
    rng = np.random.default_rng(seed)
    n_req = max(1, int(rate_hz * duration_s))
    gaps = rng.exponential(1.0 / rate_hz, n_req)
    futs = []
    t0 = time.perf_counter()
    t_next = 0.0
    for i in range(n_req):
        t_next += gaps[i]
        lag = t_next - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        A, RHS = tenants[rng.integers(n_tenants)]
        futs.append(svc.submit(
            A, RHS[:, int(rng.integers(k_pool))],
            certified_rtol=rtol, mode="session",
        ))
    resps = [f.result(timeout=60.0) for f in futs]
    wall = time.perf_counter() - t0
    svc.stop()
    _check_all_certified(resps, rtol)
    lat = np.sort([r.latency_s for r in resps])
    stats = svc.stats()
    row = {
        "name": "serve_open_loop",
        "m": m, "n": n, "rate_hz": rate_hz, "n_requests": n_req,
        "n_tenants": n_tenants,
        "solves_per_s_achieved": n_req / wall,
        "p50_s": float(np.percentile(lat, 50)),
        "p99_s": float(np.percentile(lat, 99)),
        "cache_hit_rate": stats["cache"]["hit_rate"],
        "mean_batch_occupancy": stats["session_occupancy"],
        "all_certified": True,
    }
    emit(
        "serve/open_loop", row["p99_s"],
        f"p50={row['p50_s'] * 1e3:.2f}ms;p99={row['p99_s'] * 1e3:.2f}ms;"
        f"hit={row['cache_hit_rate']:.2f};occ={row['mean_batch_occupancy']:.2f}",
    )
    return [row]


def run(m=12000, n=80, full=False, smoke=False):
    """Returns serve rows (also emitted as CSV) for ``run.py --json``."""
    if full:
        m, n = 20000, 100
    if smoke:
        rows = closed_loop(3000, 40, k=16)
        rows += open_loop(2000, 32, rate_hz=120.0, duration_s=1.0,
                          n_tenants=2)
    else:
        rows = closed_loop(m, n)
        rows += open_loop(4000, 60)
    return rows


def main():
    import argparse

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + ~1s open loop (CI examples job)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = run(full=args.full, smoke=args.smoke)
    speed = next(r for r in rows if r["name"] == "serve_speedup")
    print(f"speedup: cold {speed['speedup']:.2f}x, "
          f"warm {speed['speedup_warm']:.2f}x over per-request certified lstsq")


if __name__ == "__main__":
    main()
