"""Backend sweep: the Pallas sketch kernels measured END-TO-END.

The paper's headline speedup lives or dies on the sketch apply inside the
full solve, so this bench no longer times kernels in isolation: for every
kernel-backed sketch kind it runs ``saa_sas`` twice — ``backend="reference"``
(pure-jnp applies) vs ``backend="pallas"`` (the ``repro.kernels`` ops) — and
reports both, plus the analytically-derived TPU roofline terms of the apply.

On this CPU container the pallas rows execute in ``interpret=True`` mode, so
their wall times measure the *kernel semantics*, not TPU performance; the
``derived`` column's HBM bytes / MXU flops / v5e roofline times are the
numbers the §Perf log tracks.

Two PR 6 sweeps ride along:

- **fused vs unfused** — ``sketch_qr`` (sketch feeding shifted-CholeskyQR3
  directly, BLAS3-rate finish, fused Gram on the pallas backend) against
  the seed pipeline ``op.apply_op`` → ``jnp.linalg.qr`` (Householder).
  Measured on the reference backend so the wall times are real compute,
  not interpret-mode overhead; the acceptance row is the largest shape.
- **bf16 vs fp32 sketch** — full certified solves with
  ``precision="mixed"`` vs ``"full"``, reporting wall time AND the
  certified forward-error bound, plus the true error vs QR: the claim
  under test is that the cheap sketch loses *no certified accuracy*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SketchedFactor, generate_problem, resolve_backend, saa_sas
from repro.core.lstsq import lstsq
from repro.core.precond import _sketch_apply
from repro.core.sketch import sample as sample_sketch
from repro.kernels.tsqr import sketch_qr
from repro.launch.mesh import HW

from .common import emit, time_fn

BACKENDS = ("reference", "pallas")
KINDS = ("countsketch", "srht", "gaussian", "uniform_dense")

# (m, n) sweep for fused-vs-unfused; the LAST entry is the acceptance shape.
# Tall-skinny (m ≫ n) is the paper's regime and the one the fused pipeline
# targets: at fat aspect ratios the O(m·n·d) apply dominates both pipelines
# equally and the ratio degenerates to 1.0.
FUSED_SHAPES = ((4096, 64), (8192, 64), (16384, 128), (32768, 128))
FUSED_KINDS = ("countsketch", "srht", "gaussian", "uniform_dense")


def _derived_apply_terms(kind: str, m: int, n: int, d: int) -> str:
    """Roofline terms of ONE sketch apply S·[A|b] at v5e constants."""
    nn = n + 1  # the solvers sketch A and b
    if kind == "countsketch":
        hbm = (m * nn + d * nn) * 4 + m * 8
        flops = 2 * m * d * nn  # one-hot matmul recast
    elif kind == "srht":
        m_pad = 1 << (m - 1).bit_length()
        c = min(1024, m_pad)
        r = m_pad // c
        hbm = 2 * (m_pad * nn * 4) * 2 + d * nn * 4  # two streamed passes
        flops = 2 * m_pad * nn * (r + c)
    elif kind == "gaussian":
        # fused-PRNG: S never touches HBM
        hbm = (m * nn + d * nn) * 4
        flops = 2 * m * d * nn
    else:  # uniform_dense: materialized S streamed from HBM
        hbm = (d * m + m * nn + d * nn) * 4
        flops = 2 * m * d * nn
    t_mem = hbm / HW["hbm_bw"]
    t_mxu = flops / HW["peak_flops_bf16"]
    bound = "mem" if t_mem > t_mxu else "mxu"
    return (
        f"hbm_bytes={hbm};mxu_flops={flops};"
        f"v5e_mem_us={t_mem*1e6:.1f};v5e_mxu_us={t_mxu*1e6:.1f};bound={bound}"
    )


def _fused_sweep(seed=0):
    """Fused ``sketch_qr`` vs unfused apply → Householder QR, per kind/shape.

    Reference-backend wall times (real compute on this host; interpret-mode
    pallas wall times say nothing about TPU perf).  The fused pipeline is
    compiled as ONE computation — ``jax.jit`` around the whole
    apply → Gram → shifted-CholeskyQR3 chain, so XLA fuses the stages and
    B=SA never round-trips between dispatches — against the seed pipeline's
    two staged steps (``op.apply_op`` then LAPACK Householder QR), which is
    exactly the fused/unfused distinction.  Wins are largest in the paper's
    tall-skinny regime where the (s, n) QR and the apply's elementwise
    pre/post stages (SRHT's D-scale + gather, CountSketch's scatter) are a
    real fraction of the pipeline.
    """
    for m, n in FUSED_SHAPES:
        d = 4 * n
        A = jax.random.normal(jax.random.key(seed), (m, n), jnp.float64)
        for kind in FUSED_KINDS:
            op = sample_sketch(kind, jax.random.key(seed + 1), d, m)

            def unfused():
                B = op.apply_op(A, backend="reference")
                Q, R = jnp.linalg.qr(B, mode="reduced")
                return Q, R

            @jax.jit
            def fused(A):
                Q, R, _ = sketch_qr(op, A, backend="reference")
                return Q, R

            t_unfused = time_fn(lambda: unfused()[1])
            t_fused = time_fn(lambda: fused(A)[1])
            # correctness guard: |R| must agree up to row signs
            R_u = jnp.abs(unfused()[1])
            R_f = jnp.abs(fused(A)[1])
            rdiff = float(jnp.linalg.norm(R_u - R_f) / jnp.linalg.norm(R_u))
            emit(
                f"fused_qr/{kind}/m{m}_n{n}/unfused", t_unfused,
                f"m={m};n={n};d={d};pipeline=apply+householder",
            )
            emit(
                f"fused_qr/{kind}/m{m}_n{n}/fused", t_fused,
                f"m={m};n={n};d={d};pipeline=sketch_qr;"
                f"speedup={t_unfused / t_fused:.2f}x;Rdiff={rdiff:.1e}",
            )


def _mixed_sweep(seed=0, m=8192, n=64):
    """Certified solves, fp32-throughout vs bf16 sketch + fp32 refinement.

    Moderate conditioning (the regime mixed precision targets — at extreme
    cond the certified driver escalates back to full precision and the two
    columns converge).  Reports wall time, the posterior certified bound
    AND the true forward error vs QR, per sketch precision.
    """
    from repro.core import qr_solve

    prob = generate_problem(
        jax.random.key(seed), m, n, cond=1e4, beta=1e-8, method="fast"
    )
    A, b = prob.A, prob.b
    x_qr = qr_solve(A, b)
    xnorm = float(jnp.linalg.norm(x_qr))
    key = jax.random.key(seed + 1)
    for precision in ("full", "mixed"):
        def solve(precision=precision):
            return lstsq(A, b, key, accuracy="certified", precision=precision)

        t = time_fn(lambda: solve().x)
        res = solve()
        cert = res.certificate
        err = float(jnp.linalg.norm(res.x - x_qr)) / max(xnorm, 1e-300)
        emit(
            f"mixed/certified/{precision}", t,
            f"m={m};n={n};relerr={err:.3e};"
            f"bound={float(cert.rel_error_bound):.3e};"
            f"passed={int(bool(cert.passed))};esc={cert.escalations};"
            f"final_precision={cert.precision}",
        )

    # the raw sketch-apply cost the bf16 path is buying down, per kind
    for kind in FUSED_KINDS:
        d = 4 * n
        Af = A.astype(jnp.float32)
        op = sample_sketch(kind, jax.random.key(seed + 2), d, m, dtype=jnp.float32)
        t_full = time_fn(
            lambda: _sketch_apply(op, Af, backend="reference", precision="full")
        )
        t_mixed = time_fn(
            lambda: _sketch_apply(op, Af, backend="reference", precision="mixed")
        )
        emit(
            f"mixed/apply/{kind}", t_mixed,
            f"full_s={t_full:.3e};mixed_over_full="
            f"{t_mixed / max(t_full, 1e-12):.2f}x;"
            f"note=reference_backend_cast_cost_only;"
            f"tpu_bf16_mxu_rate=2x_fp32",
        )


def run(seed=0, m=8192, n=128):
    prob = generate_problem(
        jax.random.key(seed), m, n, cond=1e10, beta=1e-10, method="fast"
    )
    A, b = prob.A, prob.b
    key = jax.random.key(seed + 1)

    for kind in KINDS:
        d = 4 * n
        derived = _derived_apply_terms(kind, m, n, d)
        times = {}
        for backend in BACKENDS:
            rb = resolve_backend(backend)
            t = time_fn(
                lambda: saa_sas(
                    A, b, key, sketch=kind, sketch_size=d, backend=backend
                ).x
            )
            times[backend] = t
            r = saa_sas(A, b, key, sketch=kind, sketch_size=d, backend=backend)
            emit(
                f"e2e/saa_sas/{kind}/{backend}",
                t,
                f"backend={rb.name};interpret={int(rb.interpret)};"
                f"itn={int(r.itn)};m={m};n={n};d={d};{derived}",
            )
        emit(
            f"e2e/saa_sas/{kind}/ratio",
            times["pallas"],
            f"pallas_over_reference={times['pallas']/times['reference']:.2f}x"
            f";note=interpret-mode_wall_times_not_TPU_perf",
        )

    _fused_sweep(seed=seed)
    _mixed_sweep(seed=seed)
