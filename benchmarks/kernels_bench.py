"""Pallas-kernel micro-benchmarks.

On this CPU container the kernels execute in ``interpret=True`` mode, so
wall times measure the *reference semantics*, not TPU performance.  The
``derived`` column therefore reports the analytically-derived TPU-relevant
quantities: HBM bytes moved and MXU flops per call, plus the roofline-model
time at v5e constants — these are the numbers the §Perf log tracks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import (
    countsketch_apply,
    countsketch_ref,
    fused_gaussian_sketch,
    sketch_matmul,
    srht_apply,
)
from repro.launch.mesh import HW

from .common import emit, time_fn


def run(seed=0):
    m, n, d = 16384, 256, 1024
    A = jax.random.normal(jax.random.key(seed), (m, n), jnp.float32)

    # --- CountSketch: kernel vs segment-sum oracle -------------------------
    h = jax.random.randint(jax.random.key(1), (m,), 0, d, dtype=jnp.int32)
    s = jax.random.rademacher(jax.random.key(2), (m,), jnp.float32)
    t_ref = time_fn(lambda: countsketch_ref(A, h, s, d))
    t_int = time_fn(lambda: countsketch_apply(A, h, s, d, interpret=True))
    bytes_moved = (m * n + d * n) * 4 + m * 8
    mxu_flops = 2 * m * d * n  # one-hot matmul recast
    t_mem = bytes_moved / HW["hbm_bw"]
    t_mxu = mxu_flops / HW["peak_flops_bf16"]
    emit(
        "kernel/countsketch",
        t_int,
        f"ref_us={t_ref*1e6:.0f};hbm_bytes={bytes_moved};mxu_flops={mxu_flops};"
        f"v5e_mem_us={t_mem*1e6:.1f};v5e_mxu_us={t_mxu*1e6:.1f};"
        f"bound={'mem' if t_mem > t_mxu else 'mxu'}",
    )

    # --- SRHT: two-stage blocked Hadamard ----------------------------------
    m2 = 16384
    signs = jax.random.rademacher(jax.random.key(3), (m2,), jnp.float32)
    rows = jax.random.choice(jax.random.key(4), m2, (d,), replace=False)
    t_srht = time_fn(lambda: srht_apply(A, signs, rows, d, interpret=True))
    r, c = 16, 1024  # stage split for m=16384
    bytes_srht = 2 * (m2 * n * 4) * 2 + d * n * 4  # two streamed passes
    flops_srht = 2 * m2 * n * (r + c)
    emit(
        "kernel/srht",
        t_srht,
        f"hbm_bytes={bytes_srht};mxu_flops={flops_srht};"
        f"v5e_mem_us={bytes_srht/HW['hbm_bw']*1e6:.1f}",
    )

    # --- dense Gaussian: materialized vs fused-PRNG ------------------------
    S = jax.random.normal(jax.random.key(5), (d, m), jnp.float32)
    t_mat = time_fn(lambda: sketch_matmul(S, A, interpret=True))
    t_fused = time_fn(
        lambda: fused_gaussian_sketch(A, jax.random.key(6), d, interpret=True)
    )
    bytes_mat = (d * m + m * n + d * n) * 4
    bytes_fused = (m * n + d * n) * 4
    emit(
        "kernel/gauss_materialized",
        t_mat,
        f"hbm_bytes={bytes_mat};v5e_mem_us={bytes_mat/HW['hbm_bw']*1e6:.1f}",
    )
    emit(
        "kernel/gauss_fused_prng",
        t_fused,
        f"hbm_bytes={bytes_fused};v5e_mem_us={bytes_fused/HW['hbm_bw']*1e6:.1f};"
        f"hbm_reduction={bytes_mat/bytes_fused:.1f}x",
    )
