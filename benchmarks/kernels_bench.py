"""Backend sweep: the Pallas sketch kernels measured END-TO-END.

The paper's headline speedup lives or dies on the sketch apply inside the
full solve, so this bench no longer times kernels in isolation: for every
kernel-backed sketch kind it runs ``saa_sas`` twice — ``backend="reference"``
(pure-jnp applies) vs ``backend="pallas"`` (the ``repro.kernels`` ops) — and
reports both, plus the analytically-derived TPU roofline terms of the apply.

On this CPU container the pallas rows execute in ``interpret=True`` mode, so
their wall times measure the *kernel semantics*, not TPU performance; the
``derived`` column's HBM bytes / MXU flops / v5e roofline times are the
numbers the §Perf log tracks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import generate_problem, resolve_backend, saa_sas
from repro.launch.mesh import HW

from .common import emit, time_fn

BACKENDS = ("reference", "pallas")
KINDS = ("countsketch", "srht", "gaussian", "uniform_dense")


def _derived_apply_terms(kind: str, m: int, n: int, d: int) -> str:
    """Roofline terms of ONE sketch apply S·[A|b] at v5e constants."""
    nn = n + 1  # the solvers sketch A and b
    if kind == "countsketch":
        hbm = (m * nn + d * nn) * 4 + m * 8
        flops = 2 * m * d * nn  # one-hot matmul recast
    elif kind == "srht":
        m_pad = 1 << (m - 1).bit_length()
        c = min(1024, m_pad)
        r = m_pad // c
        hbm = 2 * (m_pad * nn * 4) * 2 + d * nn * 4  # two streamed passes
        flops = 2 * m_pad * nn * (r + c)
    elif kind == "gaussian":
        # fused-PRNG: S never touches HBM
        hbm = (m * nn + d * nn) * 4
        flops = 2 * m * d * nn
    else:  # uniform_dense: materialized S streamed from HBM
        hbm = (d * m + m * nn + d * nn) * 4
        flops = 2 * m * d * nn
    t_mem = hbm / HW["hbm_bw"]
    t_mxu = flops / HW["peak_flops_bf16"]
    bound = "mem" if t_mem > t_mxu else "mxu"
    return (
        f"hbm_bytes={hbm};mxu_flops={flops};"
        f"v5e_mem_us={t_mem*1e6:.1f};v5e_mxu_us={t_mxu*1e6:.1f};bound={bound}"
    )


def run(seed=0, m=8192, n=128):
    prob = generate_problem(
        jax.random.key(seed), m, n, cond=1e10, beta=1e-10, method="fast"
    )
    A, b = prob.A, prob.b
    key = jax.random.key(seed + 1)

    for kind in KINDS:
        d = 4 * n
        derived = _derived_apply_terms(kind, m, n, d)
        times = {}
        for backend in BACKENDS:
            rb = resolve_backend(backend)
            t = time_fn(
                lambda: saa_sas(
                    A, b, key, sketch=kind, sketch_size=d, backend=backend
                ).x
            )
            times[backend] = t
            r = saa_sas(A, b, key, sketch=kind, sketch_size=d, backend=backend)
            emit(
                f"e2e/saa_sas/{kind}/{backend}",
                t,
                f"backend={rb.name};interpret={int(rb.interpret)};"
                f"itn={int(r.itn)};m={m};n={n};d={d};{derived}",
            )
        emit(
            f"e2e/saa_sas/{kind}/ratio",
            times["pallas"],
            f"pallas_over_reference={times['pallas']/times['reference']:.2f}x"
            f";note=interpret-mode_wall_times_not_TPU_perf",
        )
