"""Certified-accuracy benchmark — the BENCH_5.json trajectory cell.

Runs every ``lstsq`` method (plus the adaptive ``accuracy="certified"``
tier) on the §5.1 ill-conditioned problem and records, per method:

- wall time (median of 3, jit-warmed),
- true forward error against QR ground truth,
- the posterior certified error bound / distortion / cond estimate
  (computed with ``repro.core.certify`` against a shared reference
  factor, so the certified-error column exists for EVERY method, not
  just the certified tier).

Rows print in the scaffold's CSV contract and are returned as dicts for
``benchmarks/run.py --json`` to dump machine-readably — the file this PR
starts tracking the perf trajectory with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SketchedFactor, generate_problem, lstsq, qr_solve
from repro.core import certify as certify_lib

from .common import emit, time_fn

METHODS = ("direct", "lsqr", "saa", "sap", "iterative", "fossils")


def run(m=8192, n=64, cond=1e10, beta=1e-10, seed=0):
    """Returns the list of row dicts (also emitted as CSV)."""
    prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
    A, b = prob.A, prob.b
    x_qr = qr_solve(A, b)
    xnorm = float(jnp.linalg.norm(x_qr))

    # One reference factor certifies every method's answer identically
    # (4n rows — the default regime the certificate's ε̂ is probed at).
    factor, _ = SketchedFactor.build(A, jax.random.key(seed + 7))
    probe_key = jax.random.key(seed + 8)
    eps_hat = float(
        certify_lib.probe_distortion(A, factor, probe_key, n_probes=8)
    )
    _, _, cond_R = certify_lib.factor_spectrum(factor)

    rows = []

    def record(name, seconds, res, escalations=None):
        err = float(jnp.linalg.norm(res.x - x_qr)) / max(xnorm, 1e-300)
        cert = res.certificate
        if cert is None:
            _, _, bound = certify_lib.error_bound(A, b, res.x, factor, eps_hat)
            rel_bound = float(bound) / max(float(jnp.linalg.norm(res.x)), 1e-300)
            distortion = eps_hat
        else:
            rel_bound = float(cert.rel_error_bound)
            distortion = float(cert.distortion)
            escalations = int(cert.escalations)
        row = {
            "name": name,
            "m": m,
            "n": n,
            "cond": cond,
            "beta": beta,
            "wall_s": seconds,
            "forward_relerr_vs_qr": err,
            "certified_rel_bound": rel_bound,
            "certified_distortion": distortion,
            "cond_estimate": float(cond_R),
            "escalations": escalations,
            "itn": int(jnp.ravel(res.itn)[0]),
        }
        rows.append(row)
        emit(
            f"certified/{name}",
            seconds,
            f"relerr={err:.3e};bound={rel_bound:.3e};eps={distortion:.2f}",
        )

    key = jax.random.key(seed + 1)
    for method in METHODS:
        def solve(method=method):
            return lstsq(A, b, key, method=method)

        seconds = time_fn(solve)
        record(method, seconds, solve())

    def solve_certified():
        return lstsq(A, b, key, accuracy="certified")

    seconds = time_fn(solve_certified)
    record("certified_auto", seconds, solve_certified())

    # the adversarial configuration: a too-small initial sketch forces
    # the escalation ladder to do its job (rows show the recovery cost)
    def solve_escalating():
        return lstsq(A, b, key, accuracy="certified", sketch_size=n + 2)

    seconds = time_fn(solve_escalating)
    record("certified_escalating", seconds, solve_escalating())

    # the mixed-precision tier: bf16 sketch apply, full-precision
    # refinement; at this cond the driver escalates back to full and the
    # row shows what the precision repair costs end to end
    def solve_mixed():
        return lstsq(A, b, key, accuracy="certified", precision="mixed")

    seconds = time_fn(solve_mixed)
    record("certified_mixed", seconds, solve_mixed())
    return rows
