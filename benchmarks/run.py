"""Benchmark harness — one module per paper table/figure.

  fig3      paper Fig. 3: runtime vs m, SAA-SAS vs LSQR
  fig4      paper Fig. 4: forward error on the ill-conditioned problem
  sketch    paper §2: operator quality/cost comparison
  kernels   Pallas kernel micro-benches (interpret mode + derived TPU terms)
  dist      distributed sketched LSQ (shard_map) + comm accounting
  stream    streaming engine: tiles/sec + peak-memory proxy vs monolithic
  certified per-method wall time + certified-error columns (BENCH_5.json)
  serve     multi-tenant solve service: closed/open-loop load rows (PR 7)
  cluster   multi-worker pass-1 scaling + kill-and-resume overhead (PR 8)
  obs       tracing-disabled overhead vs a stripped build (PR 9)
  roofline  per-cell roofline terms from the dry-run JSONs

Prints ``name,us_per_call,derived`` CSV.  ``--full`` restores paper-scale
sizes (slow on 1 CPU core).  ``--json [PATH]`` additionally dumps the
``certified`` cell's rows (per-method wall time, forward error vs QR and
the posterior certified-error columns) plus the ``serve`` cell's
throughput/latency rows as machine-readable JSON so the perf/accuracy
trajectory is tracked in git from PR 5 on.  The default path is
``BENCH_{tag}.json`` with ``--tag`` naming the trajectory point (current
PR number; ``--tag ci`` for throwaway CI runs) — committed
``BENCH_N.json`` files are what ``benchmarks/perf_gate.py`` compares
fresh runs against.
"""
import argparse
import json
import sys


def main() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,sketch,kernels,dist,stream,"
                         "certified,serve,cluster,obs,roofline")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--tag", default="9",
                    help="trajectory tag naming the default JSON path "
                         "BENCH_{tag}.json (current PR number, or 'ci')")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write the certified cell's rows as JSON "
                         "(default path: BENCH_{tag}.json; implies the "
                         "certified cell runs)")
    args = ap.parse_args()
    if args.json == "":
        args.json = f"BENCH_{args.tag}.json"
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        # --json implies the trajectory cells (certified + serve +
        # cluster + obs) run: BENCH_{tag}.json must always carry all
        # four row families.
        if (name in ("certified", "serve", "cluster", "obs")
                and args.json is not None):
            return True
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("fig4"):
        from . import error_comparison
        error_comparison.run(m=20000 if args.full else 8192,
                             n=100 if args.full else 64)
    if want("fig3"):
        from . import runtime_comparison
        runtime_comparison.run(full=args.full)
    if want("sketch"):
        from . import sketch_quality
        sketch_quality.run(m=65536 if args.full else 16384)
    if want("kernels"):
        from . import kernels_bench
        kernels_bench.run()
    if want("dist"):
        from . import distributed_bench
        distributed_bench.run()
    if want("stream"):
        from . import streaming_bench
        streaming_bench.run(m=65536 if args.full else 16384)
    rows = []
    if want("certified"):
        from . import certified_bench
        rows += certified_bench.run(m=20000 if args.full else 8192,
                                    n=100 if args.full else 64)
    if want("serve"):
        from . import serve_bench
        rows += serve_bench.run(full=args.full)
    if want("cluster"):
        from . import cluster_bench
        rows += cluster_bench.run(m=65536 if args.full else 16384)
    if want("obs"):
        from . import obs_bench
        rows += obs_bench.run()
    if args.json is not None:
        payload = {
            "bench": "certified_lstsq",
            "schema": 1,
            "rows": rows,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"wrote {args.json} ({len(rows)} rows)", file=sys.stderr)
    if want("roofline"):
        from . import roofline
        roofline.run()


if __name__ == "__main__":
    main()
