"""Benchmark harness — one module per paper table/figure.

  fig3      paper Fig. 3: runtime vs m, SAA-SAS vs LSQR
  fig4      paper Fig. 4: forward error on the ill-conditioned problem
  sketch    paper §2: operator quality/cost comparison
  kernels   Pallas kernel micro-benches (interpret mode + derived TPU terms)
  dist      distributed sketched LSQ (shard_map) + comm accounting
  stream    streaming engine: tiles/sec + peak-memory proxy vs monolithic
  roofline  per-cell roofline terms from the dry-run JSONs

Prints ``name,us_per_call,derived`` CSV.  ``--full`` restores paper-scale
sizes (slow on 1 CPU core).
"""
import argparse
import sys


def main() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,sketch,kernels,dist,stream,"
                         "roofline")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("fig4"):
        from . import error_comparison
        error_comparison.run(m=20000 if args.full else 8192,
                             n=100 if args.full else 64)
    if want("fig3"):
        from . import runtime_comparison
        runtime_comparison.run(full=args.full)
    if want("sketch"):
        from . import sketch_quality
        sketch_quality.run(m=65536 if args.full else 16384)
    if want("kernels"):
        from . import kernels_bench
        kernels_bench.run()
    if want("dist"):
        from . import distributed_bench
        distributed_bench.run()
    if want("stream"):
        from . import streaming_bench
        streaming_bench.run(m=65536 if args.full else 16384)
    if want("roofline"):
        from . import roofline
        roofline.run()


if __name__ == "__main__":
    main()
