"""Roofline table from the dry-run JSONs (experiments/dryrun)."""
from __future__ import annotations

import json
import os

from .common import emit


def load_cells(out_dir="experiments/dryrun", mesh="single"):
    d = os.path.join(out_dir, mesh)
    cells = []
    if not os.path.isdir(d):
        return cells
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            cells.append(json.load(open(os.path.join(d, f))))
    return cells


def run(out_dir="experiments/dryrun"):
    cells = load_cells(out_dir)
    if not cells:
        print("# no dry-run results found — run: python -m repro.launch.dryrun --all")
        return
    for c in cells:
        if c.get("status") != "ok" or not c.get("roofline"):
            emit(f"roofline/{c['arch']}/{c['shape']}", 0.0, f"status={c.get('status')}")
            continue
        r = c["roofline"]
        dom = r["bottleneck"]
        # Emit the time of the LABELED bottleneck so value and label agree;
        # the unconditional max() is only the fallback for bottleneck names
        # this report does not know a t_*_s field for.
        t_dom = r.get(f"t_{dom}_s")
        if t_dom is None:
            t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(
            f"roofline/{c['arch']}/{c['shape']}",
            t_dom,
            f"bottleneck={dom};t_comp={r['t_compute_s']:.3e};"
            f"t_mem={r['t_memory_s']:.3e};t_coll={r['t_collective_s']:.3e};"
            f"useful_ratio={r['useful_flops_ratio']:.3f}",
        )


def markdown_table(out_dir="experiments/dryrun"):
    """Full §Roofline markdown table (used to build EXPERIMENTS.md)."""
    cells = load_cells(out_dir)
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
        "bottleneck | MODEL/HLO flops | mem/chip (GB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok" or not c.get("roofline"):
            lines.append(
                f"| {c['arch']} | {c['shape']} | - | - | - | {c.get('status')} | - | - |"
            )
            continue
        r = c["roofline"]
        mem = c["full"]["memory"]
        mem_gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9 if mem else 0
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} | {mem_gb:.1f} |"
        )
    return "\n".join(lines)
