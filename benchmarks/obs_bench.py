"""Observability overhead: the cost of the instrumentation layer (PR 9).

One row family, one contract:

- ``obs_overhead`` — the same mid-size SAA solve timed with tracing
  *disabled* (the shipped default: every ``obs_trace.span(...)`` call
  site checks one module global and gets the shared no-op) versus a
  *stripped* build (``obs_trace.stripped()`` swaps the entry points for
  bare no-ops — the counterfactual of never having instrumented the
  code).  ``overhead_x`` = disabled / stripped wall time; the perf gate
  holds it to the ≤1.05x acceptance ceiling, i.e. tracing you did not
  ask for must cost within noise of nothing at all.
- ``traced_x`` (informational, same row) — the solve under an active
  tracer over the stripped baseline.  Tracing *synchronizes* JAX's async
  dispatch per span (``maybe_block`` — that is what makes the span
  durations honest), so this is expected to be > 1 and is not gated.

The two timed paths alternate round-robin (min over rounds) so clock
drift and cache warmth land on both sides equally.
"""
from __future__ import annotations

import time

import jax

from repro.core.lstsq import lstsq
from repro.obs import trace as obs_trace

from .common import emit


def _timed(fn, repeats: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats


def run(m=8192, n=64, rounds=6, repeats=10, smoke=False):
    if smoke:
        m, n = 2048, 32
    A = jax.random.normal(jax.random.key(0), (m, n))
    b = jax.random.normal(jax.random.key(1), (m,))
    key = jax.random.key(2)

    def solve():
        return lstsq(A, b, key, method="saa").x

    def solve_traced():
        return lstsq(A, b, key, method="saa", trace=True).x

    # warm every path once (jit compiles, tracer machinery)
    jax.block_until_ready(solve())
    jax.block_until_ready(solve_traced())
    with obs_trace.stripped():
        jax.block_until_ready(solve())

    t_disabled = t_stripped = t_traced = float("inf")
    for _ in range(rounds):
        t_disabled = min(t_disabled, _timed(solve, repeats))
        with obs_trace.stripped():
            t_stripped = min(t_stripped, _timed(solve, repeats))
        t_traced = min(t_traced, _timed(solve_traced, repeats))

    overhead = t_disabled / t_stripped
    traced_x = t_traced / t_stripped
    emit(
        "obs/disabled", t_disabled,
        f"overhead_x={overhead:.4f};m={m};n={n}",
    )
    emit("obs/stripped", t_stripped, f"m={m};n={n}")
    emit(
        "obs/traced", t_traced,
        f"traced_x={traced_x:.3f};m={m};n={n}",
    )
    return [{
        "name": "obs_overhead", "m": m, "n": n,
        "wall_s": t_disabled, "wall_s_stripped": t_stripped,
        "wall_s_traced": t_traced,
        "overhead_x": overhead, "traced_x": traced_x,
    }]


if __name__ == "__main__":
    import argparse

    jax.config.update("jax_enable_x64", True)
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI smoke lane")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        assert row["overhead_x"] <= 1.05, (
            f"tracing-disabled overhead {row['overhead_x']:.3f}x — the "
            "no-op path is doing real work"
        )
