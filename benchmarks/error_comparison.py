"""Paper Figure 4 + forward-stability comparison.

Part 1 (paper Fig. 4): forward error on the §5.1 ill-conditioned problem
(m=20000, n=100, κ=1e10, β=1e-10): SAA-SAS vs LSQR vs QR vs SAP vs the
forward-stable solvers (iterative sketching, FOSSILS), all through the
unified ``lstsq()`` result type.

Part 2 (forward-stability demo, Epperly/EMN 2024): same shape at β=1e-6
with the sketch applied in OPERATOR form (``materialize_y=False`` — the
at-scale configuration that ``repro.core.distributed`` uses, where fresh
triangular-solve rounding enters every LSQR iteration).  Plain SAA-SAS
stagnates >10x above the QR forward error there; iterative sketching and
FOSSILS stay within 10x of QR.

Part 3: forward-error vs condition-number curves, κ ∈ 1e2..1e12, for every
solver the ``lstsq()`` driver dispatches to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    fossils,
    generate_problem,
    iterative_sketching,
    lsqr_dense,
    lstsq,
    qr_solve,
    saa_sas,
    sap_sas,
)

from .common import emit, time_fn


def run(m=20000, n=100, cond=1e10, beta=1e-10, seed=0):
    prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
    A, b, xt = prob.A, prob.b, prob.x_true

    def relerr(x):
        return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))

    # ---- Part 1: paper Fig. 4 ------------------------------------------
    # QR ground truth
    t = time_fn(qr_solve, A, b)
    e_qr = relerr(qr_solve(A, b))
    emit("fig4/qr_direct", t, f"relerr={e_qr:.3e}")

    # SAA-SAS (paper algorithm, CW sketch)
    key = jax.random.key(seed + 1)
    t = time_fn(lambda: saa_sas(A, b, key))
    r = saa_sas(A, b, key)
    emit(
        "fig4/saa_sas",
        t,
        f"relerr={relerr(r.x):.3e};itn={int(r.itn)};fallback={bool(r.used_fallback)}",
    )

    # LSQR baseline (same framework)
    t = time_fn(lambda: lsqr_dense(A, b, iter_lim=4 * n))
    rl = lsqr_dense(A, b, iter_lim=4 * n)
    emit("fig4/lsqr", t, f"relerr={relerr(rl.x):.3e};itn={int(rl.itn)};istop={int(rl.istop)}")

    # SAP baseline (now warm-started through the shared factor)
    rs = sap_sas(A, b, jax.random.key(seed + 2))
    t = time_fn(lambda: sap_sas(A, b, jax.random.key(seed + 2)))
    emit("fig4/sap_sas", t, f"relerr={relerr(rs.x):.3e};itn={int(rs.itn)}")

    # Forward-stable solvers (Epperly 2024 / EMN 2024)
    ri = iterative_sketching(A, b, key)
    t = time_fn(lambda: iterative_sketching(A, b, key))
    emit("fig4/iterative_sketching", t, f"relerr={relerr(ri.x):.3e};itn={int(ri.itn)}")
    rf = fossils(A, b, key)
    t = time_fn(lambda: fossils(A, b, key))
    emit("fig4/fossils", t, f"relerr={relerr(rf.x):.3e};itn={int(rf.itn)}")

    # Sketch-size sensitivity of SAA error (paper §2.3 discussion)
    for mult in (2, 4, 8):
        r = saa_sas(A, b, key, sketch_size=mult * n)
        emit(f"fig4/saa_s{mult}n", 0.0, f"relerr={relerr(r.x):.3e};itn={int(r.itn)}")

    # ---- Part 2: forward-stability demo (operator form, β=1e-6) --------
    # Pinned to the benchmark shape where the stagnation is unambiguous.
    forward_stability(cond=cond, seed=seed)

    # ---- Part 3: forward error vs condition number ---------------------
    cond_curves(m=min(m, 8000), n=min(n, 64), beta=beta, seed=seed)


def forward_stability(m=20000, n=100, cond=1e10, beta=1e-6, seed=0):
    """Plain SAA-SAS (operator form) stagnates; iterative/FOSSILS do not."""
    prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
    A, b, xt = prob.A, prob.b, prob.x_true

    def relerr(x):
        return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))

    e_qr = relerr(qr_solve(A, b))
    key = jax.random.key(seed + 1)
    rows = [
        ("saa_sas_opform", saa_sas(A, b, key, materialize_y=False)),
        ("iterative_sketching", iterative_sketching(A, b, key)),
        ("fossils", fossils(A, b, key)),
    ]
    emit("stability/qr_direct", 0.0, f"relerr={e_qr:.3e};beta={beta:.0e}")
    for name, r in rows:
        e = relerr(r.x)
        emit(
            f"stability/{name}",
            0.0,
            f"relerr={e:.3e};vs_qr={e / e_qr:.1f}x;itn={int(r.itn)}",
        )


def cond_curves(m=8000, n=64, beta=1e-10, seed=0):
    """Forward error vs κ for every method ``lstsq()`` can dispatch to."""
    methods = ("direct", "lsqr", "saa", "sap", "iterative", "fossils")
    for cond in (1e2, 1e4, 1e6, 1e8, 1e10, 1e12):
        prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
        A, b, xt = prob.A, prob.b, prob.x_true
        for method in methods:
            res = lstsq(A, b, jax.random.key(seed + 1), method=method)
            e = float(jnp.linalg.norm(res.x - xt) / jnp.linalg.norm(xt))
            emit(
                f"cond_curve/{method}/k{cond:.0e}",
                0.0,
                f"relerr={e:.3e};itn={int(res.itn)};istop={int(res.istop)}",
            )
