"""Paper Figure 4: forward-error comparison on the §5.1 ill-conditioned
problem (m=20000, n=100, κ=1e10, β=1e-10): SAA-SAS vs LSQR vs QR vs SAP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    generate_problem,
    lsqr_dense,
    qr_solve,
    saa_sas,
    sap_sas,
)

from .common import emit, time_fn


def run(m=20000, n=100, cond=1e10, beta=1e-10, seed=0):
    prob = generate_problem(jax.random.key(seed), m, n, cond=cond, beta=beta)
    A, b, xt = prob.A, prob.b, prob.x_true

    def relerr(x):
        return float(jnp.linalg.norm(x - xt) / jnp.linalg.norm(xt))

    # QR ground truth
    t = time_fn(qr_solve, A, b)
    emit("fig4/qr_direct", t, f"relerr={relerr(qr_solve(A, b)):.3e}")

    # SAA-SAS (paper algorithm, CW sketch)
    key = jax.random.key(seed + 1)
    t = time_fn(lambda: saa_sas(A, b, key))
    r = saa_sas(A, b, key)
    emit(
        "fig4/saa_sas",
        t,
        f"relerr={relerr(r.x):.3e};itn={int(r.itn)};fallback={bool(r.used_fallback)}",
    )

    # LSQR baseline (same framework)
    t = time_fn(lambda: lsqr_dense(A, b, iter_lim=4 * n))
    rl = lsqr_dense(A, b, iter_lim=4 * n)
    emit("fig4/lsqr", t, f"relerr={relerr(rl.x):.3e};itn={int(rl.itn)};istop={int(rl.istop)}")

    # SAP baseline (paper's negative result)
    rs = sap_sas(A, b, jax.random.key(seed + 2))
    t = time_fn(lambda: sap_sas(A, b, jax.random.key(seed + 2)))
    emit("fig4/sap_sas", t, f"relerr={relerr(rs.x):.3e};itn={int(rs.itn)}")

    # Sketch-size sensitivity of SAA error (paper §2.3 discussion)
    for mult in (2, 4, 8):
        r = saa_sas(A, b, key, sketch_size=mult * n)
        emit(f"fig4/saa_s{mult}n", 0.0, f"relerr={relerr(r.x):.3e};itn={int(r.itn)}")
