"""Paper §2 operator comparison: subspace-embedding distortion and apply
cost for all six sketching operators at equal sketch size.

Every data point carries a ``backend=`` column naming the code path that
produced it (reference jnp vs pallas kernels), so BENCH_*.json trajectories
stay attributable when the per-platform default flips.  Kernel-backed kinds
are swept under both backends; kernel-less kinds run reference only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import resolve_backend, sample_sketch
from repro.core.backend import kernel_backed

from .common import emit, time_fn

OPERATORS = (
    "gaussian",
    "uniform_dense",
    "srht",
    "countsketch",
    "sparse_sign",
    "uniform_sparse",
)


def run(m=65536, n=128, d_mult=4, seed=0):
    d = d_mult * n
    # orthonormal test basis: distortion = max |sv(SQ) - 1|
    Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(seed), (m, n)))
    for kind in OPERATORS:
        op = sample_sketch(kind, jax.random.key(seed + 1), d, m)
        t_sample = time_fn(
            lambda: jax.tree.leaves(
                sample_sketch(kind, jax.random.key(seed + 1), d, m)
            )[0]
        )
        backends = ("reference", "pallas") if kernel_backed(kind) else ("reference",)
        for backend in backends:
            rb = resolve_backend(backend)
            t_apply = time_fn(lambda: op.apply(Q, backend=backend))
            sv = jnp.linalg.svd(op.apply(Q, backend=backend), compute_uv=False)
            dist = float(jnp.maximum(sv.max() - 1.0, 1.0 - sv.min()))
            emit(
                f"sketch/{kind}/{backend}",
                t_apply,
                f"backend={rb.name};interpret={int(rb.interpret)};"
                f"distortion={dist:.4f};sample_us={t_sample*1e6:.0f};d={d};m={m}",
            )
